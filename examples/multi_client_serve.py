"""End-to-end driver: N mobile clients sharing one edge uplink (CBO at scale).

Each client runs the paper's fast-tier/offload loop; all of them contend for
the same uplink and edge server. The MultiStreamServer batches every
stream's fast-tier inference into one call per round, aggregates the
low-confidence frames of all streams into one slow-tier batch, and
schedules transfers with weighted fair queueing.

``--churn`` turns the lockstep replay into a dynamic fleet: half the
clients join mid-run with ragged lifetimes, exercising the batched
``FleetRunner`` control plane's admit/retire path.

``--cells`` / ``--replicas`` / ``--placement`` / ``--trace`` put the fleet
behind an edge fabric (``src/repro/net/``): clients partitioned across C
radio cells (one serial uplink each, optionally replaying a synthetic
LTE/WiFi bandwidth trace), escalations sharded across K slow-tier replica
queues.  The defaults (1 cell, 1 replica, no trace) reproduce the legacy
single-uplink pipeline exactly.

``--trace-out trace.json`` turns on the frame-lifecycle tracer
(``repro.obs``) and exports every offloaded frame's span tree — device
pass, offload window, cell queue, upload, replica queue, batched service
— as Chrome trace-event JSON; load it in ui.perfetto.dev or
chrome://tracing.

  PYTHONPATH=src:benchmarks python examples/multi_client_serve.py --streams 8 --bw 5
  PYTHONPATH=src python examples/multi_client_serve.py --streams 8 --synthetic --churn
  PYTHONPATH=src python examples/multi_client_serve.py --streams 16 --synthetic \\
      --cells 4 --replicas 2 --placement jsq --trace lte
"""
import argparse
import os
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=8, help="number of concurrent clients")
    ap.add_argument("--bw", type=float, default=5.0, help="shared uplink Mbps")
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--latency", type=float, default=0.1)
    ap.add_argument("--frames", type=int, default=240, help="frames per stream")
    ap.add_argument("--scheduler", choices=("round_robin", "fifo"), default="round_robin")
    ap.add_argument("--policy", default="cbo",
                    help="offload policy name, or 'name0,name1,...' cycled "
                         "across streams for a heterogeneous fleet")
    ap.add_argument("--synthetic", action="store_true",
                    help="tiny synthetic tiers (no training) instead of the trained stack")
    ap.add_argument("--churn", action="store_true",
                    help="dynamic fleet: half the clients join mid-run with "
                         "ragged lifetimes (staggered join/leave)")
    ap.add_argument("--cells", type=int, default=1,
                    help="radio cells (one serial uplink each; streams "
                         "partitioned round-robin)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="slow-tier replicas (per-replica serial queues)")
    ap.add_argument("--placement", choices=("round_robin", "jsq", "least_land"),
                    default="round_robin", help="escalation -> replica placement")
    ap.add_argument("--trace", choices=("none", "lte", "wifi", "regime"),
                    default="none", help="per-cell synthetic bandwidth trace "
                                         "(scaled to --bw as the mean rate)")
    ap.add_argument("--trace-out", metavar="PATH", default=None,
                    help="record every offloaded frame's lifecycle "
                         "(queued/uploaded/placed/batched/served) and export "
                         "a Chrome trace-event JSON — open in ui.perfetto.dev "
                         "or chrome://tracing")
    args = ap.parse_args()

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

    from repro.core.netsim import Uplink, mbps
    from repro.serving import FairScheduler, MultiStreamServer, ServeConfig

    if args.synthetic:
        from benchmarks.bench_multistream import synthetic_cfg, synthetic_streams, synthetic_tiers

        cfg = synthetic_cfg(argparse.Namespace(deadline=0.2, fps=args.fps))
        fast, slow, calibrate = synthetic_tiers()
        frames, labels = synthetic_streams(args.streams, args.frames)
        acc_note = ""
    else:
        from benchmarks.common import FAST_CFG, RESOLUTIONS, SLOW_CFG, build_stack

        from repro.models import api
        from repro.models.transformer import ParallelPlan

        stack = build_stack()
        fh = api.build(FAST_CFG, ParallelPlan(remat=False))
        sh = api.build(SLOW_CFG, ParallelPlan(remat=False))
        cfg = ServeConfig(frame_rate=args.fps, resolutions=RESOLUTIONS,
                          acc_server=stack.acc_server_by_res)
        fast = lambda x: fh.forward(stack.fast_params, x)
        slow = lambda x: sh.forward(stack.slow_params, x)
        calibrate = stack.platt
        # deal each client a phase-shifted slice of the test video set
        all_f, all_l = stack.test["frames"], stack.test["labels"]
        idx = (np.arange(args.streams)[:, None] * 131 + np.arange(args.frames)[None, :]) % len(all_l)
        frames, labels = all_f[idx], all_l[idx]
        acc_note = f"  (fast tier alone: {stack.acc_fast:.3f}; slow ceiling: {stack.acc_slow:.3f})"

    uplink = Uplink(bandwidth_bps=mbps(args.bw), latency=args.latency, server_time=cfg.server_time)
    fabric = None
    if args.cells > 1 or args.replicas > 1 or args.trace != "none":
        from repro.net import EdgeFabric, lte_trace, regime_shift_trace, wifi_trace

        make_trace = {
            "none": lambda c: None,
            "lte": lambda c: lte_trace(120.0, mean_mbps=args.bw, seed=c),
            "wifi": lambda c: wifi_trace(120.0, good_mbps=args.bw, bad_mbps=args.bw / 8, seed=c),
            "regime": lambda c: regime_shift_trace((args.bw, args.bw / 8), period=10.0),
        }[args.trace]
        fabric = EdgeFabric.build(
            n_streams=args.streams, n_cells=args.cells, n_replicas=args.replicas,
            bandwidth_bps=mbps(args.bw), latency=args.latency,
            server_time=cfg.server_time, placement=args.placement,
            traces=[make_trace(c) for c in range(args.cells)],
            serial_replicas=args.replicas > 1)
    names = args.policy.split(",")
    policy = names[0] if len(names) == 1 else (lambda s: names[s % len(names)])
    telemetry = None
    if args.trace_out:
        from repro.obs import Telemetry

        telemetry = Telemetry(record=True, trace=True)
    server = MultiStreamServer(cfg, fast, slow, calibrate,
                               uplink if fabric is None else None,
                               n_streams=args.streams,
                               scheduler=FairScheduler(args.scheduler), policy=policy,
                               fabric=fabric, telemetry=telemetry)
    schedule = None
    if args.churn:
        from benchmarks.bench_multistream import churn_schedule

        schedule = churn_schedule(args.streams, frames.shape[1], cfg)
    metrics = server.process_streams(frames, labels, schedule=schedule)

    print(f"\n=== {args.policy} multi-client serving: {args.streams} streams @ "
          f"{args.bw} Mbps shared, {args.fps} fps, L={args.latency*1e3:.0f} ms, "
          f"{args.scheduler} ===")
    for k, v in metrics.summary().items():
        print(f"  {k:22s} {v}")
    if acc_note:
        print(acc_note)
    print("\n  per-stream:")
    for s, m in enumerate(metrics.per_stream):
        print(f"    stream {s:3d}: acc={m.accuracy:.3f} offload={m.offload_frac:.3f} "
              f"miss={m.deadline_miss_frac:.3f}")
    if telemetry is not None:
        path = telemetry.tracer.export_chrome_trace(args.trace_out)
        att = telemetry.tracer.miss_attribution()
        print(f"\n  frame-lifecycle trace: {telemetry.tracer.n_frames} offloads "
              f"-> {path}")
        print(f"  miss attribution: {att['misses']} misses "
              f"({att['radio']} radio-dominant, {att['slow_tier']} slow-tier)")


if __name__ == "__main__":
    main()
