"""Quickstart: the CBO cascade in ~60 lines.

Builds a tiny two-tier stack on synthetic video frames, calibrates the fast
tier's confidence, and runs one confidence-gated batch through the cascade.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ResNetConfig
from repro.core.calibration import PlattCalibrator, ece
from repro.core.cascade import cascade_classify
from repro.core.confidence import max_softmax
from repro.data.video import VideoDataConfig, make_dataset
from repro.models import api
from repro.models.transformer import ParallelPlan
from repro.quant.quantize import qdq_tree


def main():
    # 1. data: class-conditional synthetic video frames with difficulty skew
    data = make_dataset(VideoDataConfig(n_classes=10, img_res=32), n_videos=40, seed=0)
    frames, labels = jnp.asarray(data["frames"][:64]), data["labels"][:64]

    # 2. two tiers: a small int8-quantized "NPU" model + a larger fp model
    fast_cfg = ResNetConfig(name="fast", img_res=32, depths=(1,), width=8, n_classes=10)
    slow_cfg = ResNetConfig(name="slow", img_res=32, depths=(2, 2), width=32, n_classes=10)
    fast = api.build(fast_cfg, ParallelPlan(remat=False))
    slow = api.build(slow_cfg, ParallelPlan(remat=False))
    fast_params = qdq_tree(fast.init(jax.random.PRNGKey(0), dtype=jnp.float32))  # "NPU" numerics
    slow_params = slow.init(jax.random.PRNGKey(1), dtype=jnp.float32)

    # 3. calibrate the fast tier's confidence (paper §III-B)
    logits = fast.forward(fast_params, frames)
    conf = np.asarray(max_softmax(logits))
    correct = (np.argmax(np.asarray(logits), -1) == labels).astype(float)
    platt = PlattCalibrator.fit(conf, correct)
    print(f"uncalibrated ECE={ece(conf, correct):.3f} -> calibrated ECE={ece(np.asarray(platt(conf)), correct):.3f}")

    # 4. one cascade batch: escalate the K=16 least-confident frames
    out = cascade_classify(
        lambda x: fast.forward(fast_params, x),
        lambda x: slow.forward(slow_params, x),
        platt,
        frames,
        threshold=0.6,
        capacity=16,
        resolution=24,
    )
    print(f"escalated {int(np.asarray(out.escalated).sum())}/64 frames "
          f"(mean conf {float(out.conf.mean()):.3f})")
    print("final predictions:", np.asarray(out.preds)[:16], "...")


if __name__ == "__main__":
    main()
