"""Training driver with fault tolerance: train a fast-tier model with the
framework's Trainer, inject a simulated node failure mid-run, and watch the
supervisor restart from the last async checkpoint.

  PYTHONPATH=src python examples/train_fast_tier.py [--steps 120]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ResNetConfig
from repro.data.pipeline import DeterministicPipeline, PipelineConfig, image_batch_fn
from repro.data.video import VideoDataConfig, make_dataset
from repro.models import api
from repro.models.transformer import ParallelPlan
from repro.train import optim
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    args = ap.parse_args()

    data = make_dataset(VideoDataConfig(n_classes=10, img_res=32), n_videos=240, seed=0)
    cfg = ResNetConfig(name="fast-tier", img_res=32, depths=(1, 1), width=16, n_classes=10)
    h = api.build(cfg, ParallelPlan(remat=False))
    params = h.init(jax.random.PRNGKey(0), dtype=jnp.float32)
    print(f"model: {h.n_params():,} params")

    pipe = DeterministicPipeline(PipelineConfig(global_batch=128, seed=0),
                                 image_batch_fn(data), len(data["labels"]))
    tcfg = TrainConfig(
        n_steps=args.steps,
        ckpt_every=20,
        ckpt_dir=args.ckpt_dir,
        log_every=20,
        fail_at_step=args.steps // 2,  # fault-tolerance drill
        ocfg=optim.OptimConfig(lr=3e-3, weight_decay=1e-4),
    )
    trainer = Trainer(tcfg, lambda p, b: h.loss(p, b), params, pipe)
    out = trainer.run_with_restarts(max_restarts=1)
    print(f"finished: {out}")


if __name__ == "__main__":
    main()
