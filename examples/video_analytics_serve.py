"""End-to-end driver: CBO video-analytics serving (the paper's system).

Streams synthetic video through the CascadeServer: fast int8 tier answers
everything instantly; the CBO controller (Algorithm 1) adaptively escalates
low-confidence frames over a bandwidth-limited uplink; deadline-missed
escalations fall back to the fast answer (straggler mitigation).

  PYTHONPATH=src:benchmarks python examples/video_analytics_serve.py [--bw 5]
"""
import argparse
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bw", type=float, default=5.0, help="uplink Mbps")
    ap.add_argument("--fps", type=float, default=30.0)
    ap.add_argument("--latency", type=float, default=0.1)
    ap.add_argument("--frames", type=int, default=480)
    ap.add_argument("--policy", default="cbo",
                    help="offload policy registry name (see docs/policies.md)")
    args = ap.parse_args()

    import os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import FAST_CFG, RESOLUTIONS, SLOW_CFG, build_stack

    from repro.core.netsim import Uplink, mbps
    from repro.models import api
    from repro.models.transformer import ParallelPlan
    from repro.serving.engine import CascadeServer, ServeConfig

    stack = build_stack()
    fh = api.build(FAST_CFG, ParallelPlan(remat=False))
    sh = api.build(SLOW_CFG, ParallelPlan(remat=False))

    cfg = ServeConfig(
        frame_rate=args.fps,
        resolutions=RESOLUTIONS,
        acc_server=stack.acc_server_by_res,
    )
    uplink = Uplink(bandwidth_bps=mbps(args.bw), latency=args.latency, server_time=cfg.server_time)
    server = CascadeServer(
        cfg,
        fast_forward=lambda x: fh.forward(stack.fast_params, x),
        slow_forward=lambda x: sh.forward(stack.slow_params, x),
        calibrate=stack.platt,
        uplink=uplink,
        policy=args.policy,
    )
    frames = stack.test["frames"][: args.frames]
    labels = stack.test["labels"][: args.frames]
    metrics = server.process_stream(frames, labels)
    print(f"\n=== {args.policy} serving @ {args.bw} Mbps, {args.fps} fps, "
          f"L={args.latency*1e3:.0f} ms ===")
    for k, v in metrics.summary().items():
        print(f"  {k:22s} {v}")
    print(f"  (fast tier alone: {stack.acc_fast:.3f}; slow tier ceiling: {stack.acc_slow:.3f})")


if __name__ == "__main__":
    main()
