"""Calibration workflow: fit Platt / isotonic / temperature on a calibration
split, compare ECE/MCE (paper Table I), then plan offloads with Algorithm 1
under a live bandwidth estimate.

  PYTHONPATH=src:benchmarks python examples/calibrate_and_deploy.py
"""
import sys

import numpy as np


def main():
    import os

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.common import RESOLUTIONS, build_stack

    from repro.core.calibration import IsotonicCalibrator, PlattCalibrator, ece, mce
    from repro.core.netsim import mbps, png_size_model
    from repro.policy import Env, Frame, make_policy

    stack = build_stack()
    conf, correct = stack.calib["conf"], stack.calib["correct"]
    n = len(conf) // 2
    print("=== calibration quality (holdout) ===")
    print(f"{'method':14s} {'ECE':>7s} {'MCE':>7s}")
    print(f"{'uncalibrated':14s} {ece(conf[n:], correct[n:]):7.3f} {mce(conf[n:], correct[n:]):7.3f}")
    for name, cal in [("platt", PlattCalibrator.fit(conf[:n], correct[:n])),
                      ("isotonic", IsotonicCalibrator.fit(conf[:n], correct[:n]))]:
        c = np.asarray(cal(conf[n:]))
        print(f"{name:14s} {ece(c, correct[n:]):7.3f} {mce(c, correct[n:]):7.3f}")

    # deploy: plan the next offloads from a backlog of 8 frames through the
    # policy plane (any registered policy works here — docs/policies.md)
    platt = PlattCalibrator.fit(conf, correct)
    cal = np.asarray(platt(conf[:8]))
    frames = [Frame(arrival=i / 30.0, conf=float(cal[i]),
                    sizes=tuple(png_size_model(r, base_res=32, base_bytes=60000.0) for r in RESOLUTIONS))
              for i in range(8)]
    env = Env(bandwidth=mbps(5.0), latency=0.1, server_time=0.037, deadline=0.2,
              acc_server=stack.acc_server_by_res)
    policy = make_policy("cbo")
    policy.observe(frames)
    plan = policy.plan(0.0, env)
    print("\n=== CBO plan @5 Mbps ===")
    print(f"theta={plan.theta:.3f}  resolution={RESOLUTIONS[plan.resolution]}px")
    print(f"planned offloads (frame, res): {[(i, RESOLUTIONS[r]) for i, r in plan.offloads]}")
    print(f"expected accuracy gain: +{plan.total_gain:.2f} over {len(frames)} frames")


if __name__ == "__main__":
    main()
